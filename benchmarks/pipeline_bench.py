"""Async pipelined dispatch (PR 9) vs the synchronous PR-8 runtime.

Two lanes, both comparing ``REPRO_PIPELINE_DEPTH=1`` (the exact PR-8
synchronous executor) against depth 2 (async dispatch + donation +
chunk prefetch + serving rebatching) in one process — the depth is read
per plan run, so both modes share warm jit executables where their keys
coincide:

  * **streamed append-retrain** — warm incremental retrain of lmDS
    after a 10% row append under a 10x-undersized memory budget. The
    chunk-cache keys are bitwise identical across depths (the pipelined
    loop derives bucket fingerprints from the leaf's block-sum table
    instead of re-hashing every slice), so the warm lane measures the
    same cache hits minus the removed fingerprint pass; depth 2 must
    be >= `min_speedup` faster, with results equal to 1e-10, zero
    timed-lane retraces, and `peak_live_bytes` (charging BOTH in-flight
    buckets) within the budget.
  * **serving sustained QPS** — the scoring server under seeded-Poisson
    open-loop load with continuous rebatching on; must sustain
    >= `qps_floor` (the PR-7 closed baseline) with zero hot-path
    retraces, and single-row results bitwise across depths.

Appends a trajectory entry to ``benchmarks/BENCH_pipeline.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from .common import emit

BENCH_JSON = os.path.join(os.path.dirname(__file__), "BENCH_pipeline.json")


def _lm_ref(Xh, yh, reg=1e-3):
    return np.linalg.solve(Xh.T @ Xh + reg * np.eye(Xh.shape[1]),
                           Xh.T @ yh)


def _lm_run(rt, Xh, yh, reg=1e-3):
    from repro.core.dag import input_tensor
    from repro.lifecycle.regression import lmDS
    X = input_tensor("X", Xh)
    y = input_tensor("y", yh)
    return np.asarray(lmDS(X, y, reg=reg, runtime=rt)).ravel()


def _append_lane(rows: int, cols: int, budget_ratio: int, repeats: int,
                 min_speedup: float) -> dict:
    from repro.core import costmodel
    from repro.core.jit_cache import get_jit_cache
    from repro.core.reuse import ReuseCache
    from repro.core.runtime import LineageRuntime

    rng = np.random.default_rng(9)
    Xh = rng.normal(size=(rows, cols))
    yh = rng.normal(size=(rows,))
    extra = rows // 10
    arng = np.random.default_rng(109)
    Xa = np.vstack([Xh, arng.normal(size=(extra, cols))])
    ya = np.concatenate([yh, arng.normal(size=(extra,))])
    ref = _lm_ref(Xa, ya).ravel()
    budget = int(Xh.nbytes // budget_ratio)
    jstats = get_jit_cache().stats

    saved_budget = costmodel.CHUNK_MEM_BUDGET
    out: dict = {}
    try:
        costmodel.CHUNK_MEM_BUDGET = budget
        for depth in ("1", "2"):
            os.environ["REPRO_PIPELINE_DEPTH"] = depth
            # unmeasured warm cycle per depth: compiles this depth's
            # executables (depth 2 adds |don:-keyed variants) so the
            # timed lane is pure steady state
            wrt = LineageRuntime(cache=ReuseCache(), fuse=True)
            _lm_run(wrt, Xh, yh)
            _lm_run(wrt, Xa, ya)
            ts = []
            for _ in range(repeats):
                rt = LineageRuntime(cache=ReuseCache(), fuse=True)
                _lm_run(rt, Xh, yh)        # base training populates
                s = rt.stats.streaming     # the chunk-partial cache
                b_chunks = s.chunks
                miss0 = jstats.misses
                t0 = time.perf_counter()
                got = _lm_run(rt, Xa, ya)
                ts.append(time.perf_counter() - t0)
                retraces = jstats.misses - miss0
                assert retraces == 0, \
                    f"depth {depth}: {retraces} timed-lane retraces"
                assert np.abs(got - ref).max() < 1e-10
                assert s.chunks_reused == b_chunks, \
                    "append shifted existing chunk boundaries"
                assert 0 < s.peak_live_bytes <= budget, \
                    f"depth {depth}: live {s.peak_live_bytes} > {budget}"
            out[depth] = dict(t=float(np.median(ts)), rt=rt)
        p = out["2"]["rt"].stats.pipeline
        assert p.prefetch_issued > 0, "prefetch never engaged"
        assert out["1"]["rt"].stats.pipeline.total == 0
    finally:
        costmodel.CHUNK_MEM_BUDGET = saved_budget
        os.environ.pop("REPRO_PIPELINE_DEPTH", None)

    t_sync, t_pipe = out["1"]["t"], out["2"]["t"]
    speedup = t_sync / t_pipe
    assert speedup >= min_speedup, \
        f"pipelined append-retrain only {speedup:.2f}x over the " \
        f"synchronous path (>= {min_speedup}x required)"
    pdict = p.as_dict()
    return dict(budget=budget, t_sync=t_sync, t_pipe=t_pipe,
                speedup=speedup, overlap_ratio=pdict["overlap_ratio"],
                prefetch_issued=pdict["prefetch_issued"],
                prefetch_hits=pdict["prefetch_hits"],
                donated_buffers=pdict["donated_buffers"],
                peak_live_bytes=int(
                    out["2"]["rt"].stats.streaming.peak_live_bytes))


def _serving_lane(d: int, rate: float, openloop_n: int,
                  qps_floor: float) -> dict:
    from repro.core import LineageRuntime
    from repro.serving import ModelServer
    from benchmarks.serving_bench import _make_script, _open_loop

    rng = np.random.default_rng(11)
    probe_rows = [rng.normal(size=(1, d)) for _ in range(32)]
    got = {}
    try:
        for depth in ("1", "2"):
            os.environ["REPRO_PIPELINE_DEPTH"] = depth
            rt = LineageRuntime()
            script = _make_script(d, rt, np.random.default_rng(7))
            with ModelServer(script, runtime=rt, max_batch=16,
                             max_wait_us=2000.0) as server:
                got[depth] = [server.score(x)[0] for x in probe_rows]
                if depth == "2":
                    run = _open_loop(server, d, rate, openloop_n,
                                     seed=int(rate))
                    log = rt.stats.serving
                    assert log.retraces == 0, \
                        f"hot path recompiled {log.retraces}x"
                    rebatches = rt.stats.pipeline.rebatches
    finally:
        os.environ.pop("REPRO_PIPELINE_DEPTH", None)
    for a, b in zip(got["1"], got["2"], strict=True):
        assert np.array_equal(a, b), \
            "depth-2 serving diverged from the synchronous dispatcher"
    assert run["qps"] >= qps_floor, \
        f"sustained {run['qps']:.0f} qps with rebatching " \
        f"(>= {qps_floor:.0f} required)"
    assert rebatches > 0, "rebatching never overlapped a batch"
    return dict(run=run, rebatches=int(rebatches))


def main(rows: int = 131072, cols: int = 256, budget_ratio: int = 10,
         repeats: int = 3, min_speedup: float = 1.15,
         d: int = 256, rate: float = 3000.0, openloop_n: int = 600,
         qps_floor: float = 2105.0) -> dict:
    from repro.core import clear_jit_cache

    clear_jit_cache()
    app = _append_lane(rows, cols, budget_ratio, repeats, min_speedup)
    srv = _serving_lane(d, rate, openloop_n, qps_floor)

    emit("pipeline_append_retrain", app["t_pipe"],
         f"sync_us={app['t_sync']*1e6:.0f};"
         f"speedup={app['speedup']:.2f}x;"
         f"overlap={app['overlap_ratio']:.2f}")
    emit("pipeline_serving_openloop", srv["run"]["p50_us"] * 1e-6,
         f"qps={srv['run']['qps']:.0f};rebatches={srv['rebatches']};"
         f"idle_frac={srv['run']['idle_frac']:.2f}")

    entry = dict(
        benchmark="pipeline_async",
        workload=f"lmDS append {rows}x{cols} budget=nbytes/"
                 f"{budget_ratio}; serve (1x{d}) @ {rate:.0f}qps",
        budget_bytes=app["budget"],
        append_sync_us_per_call=round(app["t_sync"] * 1e6, 1),
        append_pipelined_us_per_call=round(app["t_pipe"] * 1e6, 1),
        append_speedup=round(app["speedup"], 2),
        overlap_ratio=app["overlap_ratio"],
        prefetch_issued=app["prefetch_issued"],
        prefetch_hits=app["prefetch_hits"],
        donated_buffers=app["donated_buffers"],
        peak_live_bytes=app["peak_live_bytes"],
        serving_qps=round(srv["run"]["qps"], 1),
        serving_p50_us=round(srv["run"]["p50_us"], 1),
        serving_p99_us=round(srv["run"]["p99_us"], 1),
        serving_idle_frac=round(srv["run"]["idle_frac"], 3),
        rebatches=srv["rebatches"],
        retraces=0,
        parity="bitwise (serving), 1e-10 (streamed lmDS)",
        ts=time.strftime("%Y-%m-%dT%H:%M:%S"),
    )
    trajectory = []
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                trajectory = json.load(f)
        except Exception:
            trajectory = []
    trajectory.append(entry)
    with open(BENCH_JSON, "w") as f:
        json.dump(trajectory, f, indent=2)
    return entry


if __name__ == "__main__":
    import sys
    sys.path.insert(0, "src")
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    print("name,us_per_call,derived")
    if "--smoke" in sys.argv:
        # smaller matrix + relaxed floors on shared CI cores; the full
        # run holds the >= 1.15x / >= 2105 qps acceptance bars
        out = main(rows=16384, repeats=2, min_speedup=1.05,
                   d=64, rate=2600.0, openloop_n=300, qps_floor=1200.0)
    else:
        out = main()
    print(json.dumps(out, indent=2))
