"""Pallas TPU kernels for block-sparse matmul/gram (SpMM behind `bcoo`).

TPU adaptation of SystemDS's sparse blocks (DESIGN.md §2a): on TPU,
sparsity exploitation is *block-level*, not value-level — the MXU eats
dense 128×128 tiles, so the win is skipping tiles whose operand blocks
are entirely zero. Each kernel takes a scalar-prefetched int32 block
nonzero-count map (computed once per operand, see `ops.block_mask`) and
gates the MXU work of a grid step on it with `pl.when`:

  * `gram_block_sparse`  — G = X^T X over column tiles of X, skipping
    (k, i)/(k, j) row-block pairs with no nonzeros; upper-triangle only
    (the tsmm trick), mirrored by the wrapper like `kernels.gram`.
  * `spmm_block_sparse`  — Y = X @ W, skipping zero (i, k) blocks of X.
  * `xtv_block_sparse`   — X^T v without materializing t(X), skipping
    zero row blocks.

At density d with uniformly scattered nonzeros most blocks are nonempty,
but ML sparsity is rarely uniform (empty feature column groups, padded
row ranges, graph locality) — block masks capture exactly that case.
Block loads still stream HBM→VMEM (BlockSpec copies are unconditional);
what the mask saves is MXU work, which dominates for gram/SpMM tiles.

`interpret=True` runs the same kernel body on CPU for tests; the
dispatch layer (`ops.py`) uses BCOO math off-TPU, mirroring
`kernels/rwkv6`'s kernel/ops/ref split.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific grid spec; absent on some CPU-only installs
    from jax.experimental.pallas import tpu as pltpu
    HAS_PLTPU = True
except Exception:  # pragma: no cover
    pltpu = None
    HAS_PLTPU = False

DEFAULT_BM = 512
DEFAULT_BN = 256


def _gram_kernel(mask_ref, xi_ref, xj_ref, out_ref):
    """One (i, j, k) step: out += Xi^T @ Xj when both blocks are nonzero."""
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when((j >= i) & (mask_ref[k, i] > 0) & (mask_ref[k, j] > 0))
    def _accum():
        out_ref[...] += jax.lax.dot_general(
            xi_ref[...], xj_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def gram_block_sparse(x: jnp.ndarray, mask: jnp.ndarray, *,
                      bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      interpret: bool = False) -> jnp.ndarray:
    """Upper-triangle block-sparse gram; caller mirrors (see ops)."""
    m, n = x.shape
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    assert mask.shape == (m // bm, n // bn), (mask.shape, m // bm, n // bn)
    n_i = n // bn
    grid = (n_i, n_i, m // bm)
    in_specs = [
        pl.BlockSpec((bm, bn), lambda i, j, k, *_: (k, i)),
        pl.BlockSpec((bm, bn), lambda i, j, k, *_: (k, j)),
    ]
    out_spec = pl.BlockSpec((bn, bn), lambda i, j, k, *_: (i, j))
    out_shape = jax.ShapeDtypeStruct((n, n), jnp.float32)
    if HAS_PLTPU:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=in_specs, out_specs=out_spec)
        return pl.pallas_call(_gram_kernel, grid_spec=grid_spec,
                              out_shape=out_shape,
                              interpret=interpret)(mask, x, x)
    # pragma: no cover — pltpu unavailable; interpret-mode fallback where
    # the mask rides along as a regular (whole-array) input
    return pl.pallas_call(
        _gram_kernel, grid=grid,
        in_specs=[pl.BlockSpec(mask.shape, lambda i, j, k: (0, 0))]
        + in_specs,
        out_specs=out_spec, out_shape=out_shape,
        interpret=True)(mask, x, x)


def _spmm_kernel(mask_ref, x_ref, w_ref, out_ref):
    """One (i, k) step of Y = X @ W: out_i += X[i,k] @ W[k] if nonzero."""
    i, k = pl.program_id(0), pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(mask_ref[i, k] > 0)
    def _accum():
        out_ref[...] += jax.lax.dot_general(
            x_ref[...], w_ref[...],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def spmm_block_sparse(x: jnp.ndarray, w: jnp.ndarray, mask: jnp.ndarray, *,
                      bm: int = DEFAULT_BM, bk: int = DEFAULT_BN,
                      interpret: bool = False) -> jnp.ndarray:
    """Y = X @ W with zero blocks of X skipped (W columns ride whole)."""
    m, kdim = x.shape
    kw, c = w.shape
    assert kdim == kw and m % bm == 0 and kdim % bk == 0, (x.shape, w.shape)
    assert mask.shape == (m // bm, kdim // bk)
    grid = (m // bm, kdim // bk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, k, *_: (i, k)),
        pl.BlockSpec((bk, c), lambda i, k, *_: (k, 0)),
    ]
    out_spec = pl.BlockSpec((bm, c), lambda i, k, *_: (i, 0))
    out_shape = jax.ShapeDtypeStruct((m, c), jnp.float32)
    if HAS_PLTPU:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=in_specs, out_specs=out_spec)
        return pl.pallas_call(_spmm_kernel, grid_spec=grid_spec,
                              out_shape=out_shape,
                              interpret=interpret)(mask, x, w)
    return pl.pallas_call(  # pragma: no cover — see gram_block_sparse
        _spmm_kernel, grid=grid,
        in_specs=[pl.BlockSpec(mask.shape, lambda i, k: (0, 0))] + in_specs,
        out_specs=out_spec, out_shape=out_shape,
        interpret=True)(mask, x, w)


def _xtv_kernel(mask_ref, x_ref, v_ref, out_ref):
    """One (i, k) step of X^T v: out_i += X[k,i]^T @ v[k] if nonzero."""
    i, k = pl.program_id(0), pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(mask_ref[k, i] > 0)
    def _accum():
        out_ref[...] += jax.lax.dot_general(
            x_ref[...], v_ref[...],
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def xtv_block_sparse(x: jnp.ndarray, v: jnp.ndarray, mask: jnp.ndarray, *,
                     bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                     interpret: bool = False) -> jnp.ndarray:
    """X^T v with zero row blocks of X skipped (no t(X) materialized)."""
    m, n = x.shape
    mv, c = v.shape
    assert m == mv and m % bm == 0 and n % bn == 0, (x.shape, v.shape)
    assert mask.shape == (m // bm, n // bn)
    grid = (n // bn, m // bm)
    in_specs = [
        pl.BlockSpec((bm, bn), lambda i, k, *_: (k, i)),
        pl.BlockSpec((bm, c), lambda i, k, *_: (k, 0)),
    ]
    out_spec = pl.BlockSpec((bn, c), lambda i, k, *_: (i, 0))
    out_shape = jax.ShapeDtypeStruct((n, c), jnp.float32)
    if HAS_PLTPU:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid,
            in_specs=in_specs, out_specs=out_spec)
        return pl.pallas_call(_xtv_kernel, grid_spec=grid_spec,
                              out_shape=out_shape,
                              interpret=interpret)(mask, x, v)
    return pl.pallas_call(  # pragma: no cover — see gram_block_sparse
        _xtv_kernel, grid=grid,
        in_specs=[pl.BlockSpec(mask.shape, lambda i, k: (0, 0))] + in_specs,
        out_specs=out_spec, out_shape=out_shape,
        interpret=True)(mask, x, v)
