"""Pure-jnp oracles for the gram (tsmm) kernel family."""
from __future__ import annotations

import jax.numpy as jnp


def gram(x: jnp.ndarray) -> jnp.ndarray:
    """X^T X with f32 accumulation for low-precision inputs."""
    acc = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype
    return jnp.matmul(x.T, x, preferred_element_type=acc).astype(acc)


def xtv(x: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """X^T v with f32 accumulation for low-precision inputs."""
    acc = jnp.float32 if x.dtype in (jnp.bfloat16, jnp.float16) else x.dtype
    return jnp.matmul(x.T, v, preferred_element_type=acc).astype(acc)


def gram_aug(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Gram of the augmented matrix [X | y]: one pass yields
    [[X^T X, X^T y], [y^T X, y^T y]] — the entire lmDS sufficient statistic."""
    xy = jnp.concatenate([x, y], axis=1)
    return gram(xy)
