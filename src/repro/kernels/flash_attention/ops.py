"""Dispatching wrapper for flash attention.

Accepts model-layout tensors (B, S, H, hd) with GQA kv heads, flattens
to the kernel layout, pads sequence to block multiples, and falls back
to the chunked-jnp path off-TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel, ref


def flash_attention(q, k, v, *, causal: bool = True,
                    use_pallas: Optional[bool] = None,
                    interpret: bool = False,
                    bq: int = kernel.DEFAULT_BQ,
                    bk: int = kernel.DEFAULT_BK):
    """q: (B, Sq, Hq, hd); k/v: (B, Sk, Hkv, hd) -> (B, Sq, Hq, hd)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not (use_pallas or interpret):
        return ref.attention(q, k, v, causal=causal)
    B, Sq, Hq, hd = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    # (B, S, H, hd) -> (B*H, S, hd); kv stream shared per GQA group
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Sk, hd)
    out = kernel.flash_pallas(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                              interpret=interpret)
    return out.reshape(B, Hq, Sq, hd).transpose(0, 2, 1, 3)
