from .synthetic import gen_regression, gen_tokens  # noqa: F401
from .tokens import TokenPipeline  # noqa: F401
