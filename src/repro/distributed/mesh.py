"""Device mesh for the sharded execution backend (SystemDS's distributed
runtime as a compiler placement).

A `MeshSpec` names the two logical axes the compiler shards over:

  * ``data``   — rows of X: the paper's distributed (Spark-analogue)
    backend. `compiler.lower_distributed` propagates a row-sharded
    placement over the DAG and lowers partial reductions (gram/xtv/
    colSums/sum) to per-shard compute + `psum` on this axis.
  * ``config`` — the `parfor` bucket axis: `batching.choose_mode` may
    shard the k-configuration batch across devices instead of (on top
    of) vmapping it on one.

The spec is pure compile-time metadata (two ints) so plans can be
compiled, explained, and cost-tested without any devices forced — the
runtime resolves it to a concrete `jax.sharding.Mesh` lazily, per
process. When the host exposes fewer devices than ``data * config``
the resolution *degrades gracefully* (the `safe_spec` contract from
`repro.distributed.sharding`: an axis that does not fit replicates, it
never errors): `jax_mesh()` returns None and the runtime executes
sharded segments through the local-equivalent kernels, bit-compatible
with the sharded path.

CPU repro: run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
to get an 8-device host mesh (see tests/test_sharded.py and
benchmarks/distributed_bench.py).
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from typing import Optional

DATA_AXIS = "data"
CONFIG_AXIS = "config"


@dataclass(frozen=True)
class MeshSpec:
    """Compile-time mesh description: axis sizes for (data, config)."""

    data: int = 1
    config: int = 1

    def __post_init__(self):
        if self.data < 1 or self.config < 1:
            raise ValueError(
                f"mesh axes must be >= 1, got data={self.data} "
                f"config={self.config}")

    @property
    def ndev(self) -> int:
        return self.data * self.config

    @property
    def shape(self) -> tuple[int, int]:
        return (self.data, self.config)

    def key_tag(self) -> str:
        """Stable identity for jit-cache keys: sharded and local
        executables of one segment body must never collide, nor two
        mesh shapes with each other."""
        return f"d{self.data}xc{self.config}"

    def jax_mesh(self):
        """Resolve to a concrete `jax.sharding.Mesh`, or None when the
        process does not expose enough devices (graceful degradation —
        the caller falls back to local-equivalent execution)."""
        return _resolve_mesh(self.data, self.config)


def _resolve_mesh(data: int, config: int):
    import jax
    devices = jax.devices()
    if data * config > len(devices) or data * config < 2:
        return None
    return _cached_mesh(data, config)


# One Mesh object per (data, config) shape: shard_map closures capture
# the Mesh, and a stable object keeps AOT-compiled executables valid
# across repeated plan executions.
_mesh_cache: dict[tuple[int, int], object] = {}


def _cached_mesh(data: int, config: int):
    got = _mesh_cache.get((data, config))
    if got is None:
        import jax
        import numpy as np
        from jax.sharding import Mesh
        devs = np.asarray(jax.devices()[: data * config], dtype=object)
        got = Mesh(devs.reshape(data, config), (DATA_AXIS, CONFIG_AXIS))
        _mesh_cache[(data, config)] = got
    return got


# ---------------------------------------------------------------------------
# Active-mesh context: compile_plan picks this up, like SystemDS attaching
# a cluster to the compiler session
# ---------------------------------------------------------------------------

_active: Optional[MeshSpec] = None


def set_mesh(spec: Optional[MeshSpec]) -> None:
    global _active
    _active = spec


def get_mesh() -> Optional[MeshSpec]:
    return _active


@contextmanager
def use_mesh(data: int = 1, config: int = 1):
    """Attach a mesh to subsequently compiled plans:

        with use_mesh(data=8):
            betas, losses = grid_search_lm(X, y, lambdas)
    """
    prev = get_mesh()
    set_mesh(MeshSpec(data=data, config=config))
    try:
        yield get_mesh()
    finally:
        set_mesh(prev)


def auto_mesh(config: int = 1) -> MeshSpec:
    """A data-axis mesh over every visible device (config axis fixed)."""
    import jax
    data = max(1, len(jax.devices()) // max(config, 1))
    return MeshSpec(data=data, config=config)
