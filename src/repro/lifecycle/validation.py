"""Model validation / selection builtins (paper §5 workloads).

`grid_search_lm` is the HPO workload of Fig. 5/6: train k lmDS models
with different regularization λ over the same X — X^T X and X^T y are
λ-independent, so a reuse-enabled runtime computes them once.

`cross_validate_lm` is the CV workload of Fig. 7: k-fold cross
validation where X_train = rbind(folds ∖ i); the compensation-plan
rewrite decomposes gram/xtv over the rbind so per-fold partial products
are computed once and summed per configuration ("multiplications of the
individual folds and element-wise addition", §5.4).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core import ops
from repro.core.dag import LTensor, input_tensor
from repro.core.runtime import LineageRuntime, get_runtime


def grid_search_lm(X: LTensor, y: LTensor, lambdas: Sequence[float],
                   runtime: Optional[LineageRuntime] = None
                   ) -> tuple[np.ndarray, list[float]]:
    """Train one lmDS model per λ; returns (betas [n, k], training losses)."""
    rt = runtime or get_runtime()
    n = X.shape[1]
    betas, losses = [], []
    for lam in lambdas:
        A = ops.gram(X) + float(lam) * ops.eye(n)
        b = ops.xtv(X, y)
        beta_t = ops.solve(A, b)
        resid = y - X @ beta_t
        loss_t = ops.sum_(resid * resid)
        beta_v, loss_v = rt.evaluate([beta_t, loss_t])
        betas.append(beta_v)
        losses.append(float(loss_v))
    return np.concatenate(betas, axis=1), losses


def make_folds(x: np.ndarray, y: np.ndarray, k: int, seed: int = 42
               ) -> tuple[list[LTensor], list[LTensor]]:
    """Split into k folds ONCE as leaf tensors — stable leaves are what
    make per-fold intermediates reusable across fold iterations."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(x.shape[0])
    idxs = np.array_split(perm, k)
    fx = [input_tensor(f"foldX{i}", x[idx]) for i, idx in enumerate(idxs)]
    fy = [input_tensor(f"foldY{i}", y[idx]) for i, idx in enumerate(idxs)]
    return fx, fy


def cross_validate_lm(folds_x: list[LTensor], folds_y: list[LTensor],
                      reg: float = 1e-7,
                      runtime: Optional[LineageRuntime] = None
                      ) -> tuple[np.ndarray, list[float]]:
    """k-fold CV for lmDS; returns (betas [n, k], held-out MSEs)."""
    rt = runtime or get_runtime()
    k = len(folds_x)
    n = folds_x[0].shape[1]
    betas, errors = [], []
    for i in range(k):
        tx = [f for j, f in enumerate(folds_x) if j != i]
        ty = [f for j, f in enumerate(folds_y) if j != i]
        X = ops.rbind(*tx)
        y = ops.rbind(*ty)
        A = ops.gram(X) + reg * ops.eye(n)
        b = ops.xtv(X, y)
        beta_t = ops.solve(A, b)
        resid = folds_y[i] - folds_x[i] @ beta_t
        mse_t = ops.mean_(resid * resid)
        beta_v, mse_v = rt.evaluate([beta_t, mse_t])
        betas.append(beta_v)
        errors.append(float(mse_v))
    return np.concatenate(betas, axis=1), errors
