"""Batched serving with KV caches (prefill + decode), the serve-side
end-to-end driver:

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-0.6b
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main

if __name__ == "__main__":
    main()
