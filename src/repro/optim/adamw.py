"""AdamW on raw pytrees (no optax dependency), with global-norm clipping
and microbatch gradient accumulation.

Optimizer state is sharded exactly like the parameters (the update is
elementwise), so FSDP sharding of params automatically ZeRO-shards the
optimizer — no extra code at the distribution layer.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jnp.ndarray      # () int32
    m: Params              # first moment  (f32, like params)
    v: Params              # second moment (f32, like params)


def adamw_init(params: Params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> tuple[Params, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def adamw_update(grads: Params, state: AdamWState, params: Params, *,
                 lr: jnp.ndarray | float, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 max_grad_norm: Optional[float] = 1.0
                 ) -> tuple[Params, AdamWState, dict]:
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), \
        {"grad_norm": gnorm}


def accumulate_grads(loss_fn: Callable, params: Params, microbatches,
                     ) -> tuple[jnp.ndarray, Params]:
    """Scan over leading-dim microbatches, averaging loss and grads.

    microbatches: pytree whose leaves have shape (n_micro, ...)."""
    def body(carry, mb):
        acc_loss, acc_g = carry
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
        acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
        return (acc_loss + loss, acc_g), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss, grads), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), microbatches)
    n = jax.tree_util.tree_leaves(microbatches)[0].shape[0]
    grads = jax.tree_util.tree_map(lambda g: g / n, grads)
    return loss / n, grads
