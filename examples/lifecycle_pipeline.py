"""End-to-end data science lifecycle (the paper's core scenario):

  raw CSV  ->  generated reader  ->  schema detection  ->  cleaning
  (outliers + imputation)  ->  transformencode  ->  feature selection
  (steplm)  ->  hyper-parameter search + cross-validation with
  lineage-based reuse  ->  model checkpoint with lineage manifest.

    PYTHONPATH=src python examples/lifecycle_pipeline.py
"""
import os
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.checkpoint import store
from repro.core import LineageRuntime, ReuseCache, input_tensor
from repro.core.hetero import DataTensor, transformencode
from repro.data.csv_io import make_reader
from repro.lifecycle import (cross_validate_lm, grid_search_lm,
                             impute_by_mean, outlier_by_iqr, steplm)
from repro.lifecycle.validation import make_folds


def synthesize_messy_csv(path: str, n: int = 4000) -> np.ndarray:
    """A raw file with categoricals, outliers and missing values."""
    rng = np.random.default_rng(42)
    age = rng.integers(18, 80, n).astype(float)
    income = rng.lognormal(10, 0.5, n)
    income[rng.random(n) < 0.02] *= 50          # gross outliers
    tenure = rng.exponential(5, n)
    region = rng.choice(["north", "south", "east", "west"], n)
    score = (0.04 * age + 0.8 * np.log(income) - 0.2 * tenure
             + (region == "north") * 1.5 + rng.normal(0, 0.3, n))
    rows = []
    for i in range(n):
        inc = "" if rng.random() < 0.05 else f"{income[i]:.2f}"  # missing
        rows.append(f"{age[i]:.0f},{inc},{tenure[i]:.3f},{region[i]},"
                    f"{score[i]:.4f}")
    with open(path, "w") as f:
        f.write("\n".join(rows) + "\n")
    return score


def main():
    tmp = tempfile.mkdtemp()
    csv_path = os.path.join(tmp, "customers.csv")
    synthesize_messy_csv(csv_path)
    print(f"raw file: {csv_path} ({os.path.getsize(csv_path)} bytes)")

    # -- ingestion via a reader GENERATED from a format descriptor (§4.2)
    reader = make_reader({"delimiter": ",", "columns": [
        ("age", "f64"), ("income", "f64"), ("tenure", "f64"),
        ("region", "str"), ("score", "f64")]})
    cols = reader(csv_path)
    dt = DataTensor.from_dict(
        {k: cols[k] for k in ("age", "income", "tenure", "region")},
        types={"region": "str"})
    print("detected schema:", dt.schema)

    # -- cleaning: winsorize outliers, impute missing (mask algebra, §4.2)
    x_num = dt.numeric_matrix()
    x_num = outlier_by_iqr(input_tensor("Xraw", x_num), k=3.0,
                           repair="clip")
    x_num = impute_by_mean(input_tensor("Xclip", x_num))
    for j, name in enumerate(("age", "income", "tenure")):
        dt.columns[dt.names.index(name)] = x_num[:, j]

    # -- feature transforms -> dense matrix
    x, meta = transformencode(dt, {"age": "scale", "income": "scale",
                                   "tenure": "scale",
                                   "region": "dummycode"})
    y = cols["score"][:, None]
    print(f"feature matrix: {x.shape}, columns: {meta.out_names}")

    rt = LineageRuntime(cache=ReuseCache())
    X, Y = input_tensor("X", x), input_tensor("y", y)

    # -- forward feature selection (Example 1: steplm)
    beta_sel, selected = steplm(X, Y, max_features=5, runtime=rt)
    print("steplm selected:", [meta.out_names[i] for i in selected])

    # -- HPO sweep with lineage reuse (Fig. 5 workload).
    # mode='sequential' pins the per-λ-plan + reuse-cache execution this
    # section narrates; the default auto mode would compile the whole
    # grid into one batched vmapped plan (see examples/parfor usage in
    # README / benchmarks/parfor_bench.py) where gram/xtv never need
    # the cache — computed once in the config-invariant prefix.
    lambdas = np.logspace(-3, 2, 12).tolist()
    betas, losses = grid_search_lm(X, Y, lambdas, runtime=rt,
                                   mode="sequential")
    best = int(np.argmin(losses))
    print(f"best lambda={lambdas[best]:.4f} "
          f"(cache hits so far: {rt.cache.stats.hits})")

    # -- cross-validation with fold-decomposed partial reuse (Fig. 7)
    fx, fy = make_folds(x, y, 5, seed=0)
    cv_betas, cv_errs = cross_validate_lm(fx, fy, reg=lambdas[best],
                                          runtime=rt, mode="sequential")
    print("cv mse per fold:", np.round(cv_errs, 5))
    print("reuse stats:", rt.cache.stats.as_dict())

    # -- persist the winning model WITH its lineage (model versioning)
    ckpt = os.path.join(tmp, "ckpt")
    path = store.save(ckpt, 0, {"beta": betas[:, best:best + 1]},
                      lineage={"lambda": lambdas[best],
                               "features": meta.out_names,
                               "cv_mse": [float(e) for e in cv_errs]})
    print("model checkpointed at:", path)


if __name__ == "__main__":
    main()
